"""Serving-plane benchmark: the fused predict pipeline vs the unfused
materialize-H-then-matmul path, plus the micro-batching server under a
scripted request stream with hot-swap on and off, plus the
continuous-batching server under bursty arrivals.

Writes a machine-readable ``BENCH_serving.json`` at the repo root —
the inference-side twin of ``BENCH_stats.json``. The acceptance point
is (N=65536, L=512, bf16): the fused predict must be reported no slower
than the unfused H @ beta path.

Paths under test (both jit-compiled, never interpret mode):
  * unfused — H = g(XW + b) materialized at (N, L), then H @ beta (one
    extra HBM round trip of H).
  * fused   — on TPU the Pallas kernel (kernels/elm_predict.py, H lives
    in VMEM tiles only); elsewhere the lax.scan streaming
    implementation (kernels/elm_predict_ref.elm_predict_scan). The
    block/chunk config comes from the tuned cache per point
    (``tune=True`` refreshes TUNED_kernels.json first).

Server rows: a deterministic mixed-size request stream drained through
``serving.ELMServer`` — throughput (rows/s) and p50/p99 request latency
with the beta store hot-swapping mid-traffic vs frozen on one snapshot.

Bursty rows: the same requests arriving in *bursts* on a virtual clock,
served by tick-flushed FIFO (``ELMServer``, flush every ``tick_ms``)
vs ``ContinuousELMServer`` stepping at every arrival. Virtual time
advances by each launch's *measured* wall time plus the scripted
inter-arrival gaps, so the latency distributions mix real compute cost
with realistic queueing delay; the continuous row also checks bitwise
response parity against FIFO on the pinned stream, and an int8-beta
arm records the quantized-serving bytes/error tradeoff.

Multi-tenant rows (also the standalone ``multitenant`` suite, written
to ``BENCH_multitenant.json``): a micro-batch mixing T tenants served
by ONE stacked-beta launch (kernels/elm_predict_ops.
fused_predict_stacked) vs the per-tenant loop (T single-beta launches
over the same rows). The acceptance point is T=64 tenants x 16 rows:
the stacked path must be no slower than the loop AND the mixed batch
must go through ``serving.ELMServer`` over a ``TenantRegistry`` as
exactly one launch (``metrics["batches"] == 1``).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._bench_util import (
    fused_vs_unfused_sweep,
    paired_timeit_ms,
    tuned_fused_factory,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_serving.json")
MT_JSON = os.path.join(REPO_ROOT, "BENCH_multitenant.json")

# the acceptance point from the issue: N=65536, L=512, bf16
DEFAULT_POINT = dict(N=65536, D=64, L=512, M=8, dtype="bfloat16")
BUCKETS = (64, 256, 1024)
SLOTS = 256  # continuous-batching in-flight batch (and FIFO bucket) rows
TICK_MS = 20.0  # the FIFO arm's flush cadence under bursty arrivals

# multi-tenant acceptance: 64 tenants x 16 rows, one stacked launch
MT_POINT = dict(D=64, L=512, M=8, dtype="float32")
MT_ROWS_PER_TENANT = 16
MT_ACCEPT_T = 64


def _problem(N, D, L, M, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dt)
    W = jax.random.normal(ks[1], (D, L)).astype(dt)
    b = jax.random.normal(ks[2], (L,)).astype(jnp.float32)
    beta = jax.random.normal(ks[3], (L, M)).astype(jnp.float32)
    return X, W, b, beta


def _unfused():
    from repro.kernels.elm_predict_ref import predict_reference

    @jax.jit
    def unfused(X, W, b, beta):
        return predict_reference(X, W, b, beta, activation="sigmoid")

    return unfused


def _bench_kernel(fast, rows, records, tune):
    acceptance = fused_vs_unfused_sweep(
        fast, rows, records,
        unfused=_unfused(),
        fused_factory=tuned_fused_factory("predict", tune=tune, fast=fast),
        problem=_problem,
        flops_fn=lambda pt: 2 * pt["N"] * pt["L"] * (pt["D"] + pt["M"]),
        tag_prefix="serving", default_point=DEFAULT_POINT,
    )
    return acceptance


def _request_sizes(num_requests, rng):
    """Mixed traffic: mostly small queries, a tail of bulk scoring."""
    sizes = rng.choice(
        [1, 4, 16, 48, 200, 900], size=num_requests,
        p=[0.25, 0.25, 0.2, 0.15, 0.1, 0.05],
    )
    return [int(s) for s in sizes]


def _bench_server(fast, rows):
    from repro.core.features import make_random_features
    from repro.serving import BetaStore, ELMServer

    D, L, M, V = DEFAULT_POINT["D"], DEFAULT_POINT["L"], DEFAULT_POINT["M"], 4
    fmap = make_random_features(jax.random.key(1), D, L)
    # pin f32: benchmarks.run enables x64 for the fidelity suites, and
    # f64 betas would (correctly) push predict off the fused path
    betas0 = jax.random.normal(
        jax.random.key(2), (V, L, M), dtype=jnp.float32
    )
    num_requests = 60 if fast else 240
    submits_per_flush = 8
    publish_every = 3  # flushes between publishes on the hot-swap arm
    rng = np.random.default_rng(0)
    sizes = _request_sizes(num_requests, rng)
    queries = [
        rng.standard_normal((n, D)).astype(np.float32) for n in sizes
    ]

    # precomputed publish payloads (what stream_chunk(publish_to=...)
    # would hand over) so the timed region measures the server's swap
    # cost, not the noise generation standing in for training
    num_pubs = num_requests // submits_per_flush // publish_every + 1
    pub_betas = [
        jax.block_until_ready(betas0 + 0.01 * jax.random.normal(
            k, betas0.shape, dtype=betas0.dtype
        ))
        for k in jax.random.split(jax.random.key(3), num_pubs)
    ]

    out = {}
    for arm in ("hotswap", "frozen"):
        store = BetaStore(betas0)
        srv = ELMServer(fmap, store, buckets=BUCKETS)
        # warm the bucket programs out of the timed region (compile-once),
        # then zero ALL counters so the published stats describe only
        # the measured stream (not the warm-up's padded full buckets)
        for b in BUCKETS:
            srv.predict(np.zeros((b, D), np.float32))
        for k in srv.metrics:
            srv.metrics[k] = [] if k == "latencies_s" else 0
        if arm == "frozen":
            srv.freeze()
        flushes = 0
        t0 = time.perf_counter()
        for i, q in enumerate(queries):
            srv.submit(q)
            if (i + 1) % submits_per_flush == 0:
                srv.flush()
                flushes += 1
                if flushes % publish_every == 0:
                    store.publish(pub_betas[flushes // publish_every - 1])
        srv.flush()
        wall_s = time.perf_counter() - t0
        st = srv.stats()
        total_rows = int(sum(sizes))
        out[arm] = dict(
            wall_ms=wall_s * 1e3,
            rows_per_s=total_rows / wall_s,
            p50_ms=st["p50_ms"], p99_ms=st["p99_ms"],
            batches=st["batches"], swaps=st["swaps"],
            padding_frac=st["padding_frac"],
            served_version=srv.served_version,
        )
        rows.append((
            f"serving/server_{arm}_req{num_requests}", wall_s * 1e6,
            f"rows_per_s={out[arm]['rows_per_s']:.0f};"
            f"p50_ms={st['p50_ms']:.1f};p99_ms={st['p99_ms']:.1f};"
            f"swaps={st['swaps']};padding_frac={st['padding_frac']:.2f}",
        ))
    out["hotswap_overhead"] = out["frozen"]["rows_per_s"] / max(
        out["hotswap"]["rows_per_s"], 1e-9
    )
    out["num_requests"] = num_requests
    out["buckets"] = list(BUCKETS)
    return out


def _bursty_stream(fast, D):
    """Bursts of small requests on a virtual-ms arrival timeline."""
    rng = np.random.default_rng(7)
    num_bursts = 8 if fast else 24
    per_burst = 6
    gap_ms = 25.0
    arrivals = []  # (arrive_vt_ms, x, node)
    for bi in range(num_bursts):
        t = bi * gap_ms + float(rng.uniform(0.0, 3.0))
        for j in range(per_burst):
            n = int(rng.choice([1, 4, 16, 48], p=[0.3, 0.3, 0.25, 0.15]))
            x = rng.standard_normal((n, D)).astype(np.float32)
            arrivals.append((t + 0.1 * j, x, (bi * per_burst + j) % 4))
    return arrivals


def _drain_fifo(srv, arrivals, tick_ms):
    """Tick-flushed FIFO on virtual time; {uid: (latency_ms, y)}."""
    vt = 0.0
    submit_vt, done = {}, {}

    def flush_at(t):
        nonlocal vt
        vt = max(vt, t)
        t0 = time.perf_counter()
        served = srv.flush()
        vt += (time.perf_counter() - t0) * 1e3
        for r in served:
            done[r.uid] = (vt - submit_vt[r.uid], r.y)

    pending = 0
    for at, x, node in arrivals:
        # any tick boundaries before this arrival flush the queue
        while pending:
            tick = (vt // tick_ms + 1) * tick_ms
            if tick > at:
                break
            flush_at(tick)
            pending = 0
        vt = max(vt, at)
        uid = srv.submit(x, node=node)
        submit_vt[uid] = at
        pending += 1
    if pending:
        flush_at((vt // tick_ms + 1) * tick_ms)
    return done


def _drain_continuous(srv, arrivals):
    """Step-at-arrival continuous serving; {uid: (latency_ms, y)}."""
    vt = 0.0
    submit_vt, done = {}, {}

    def step(**kw):
        nonlocal vt
        t0 = time.perf_counter()
        served = srv.step(**kw)
        vt += (time.perf_counter() - t0) * 1e3
        for r in served:
            done[r.uid] = (vt - submit_vt[r.uid], r.y)

    for at, x, node in arrivals:
        vt = max(vt, at)
        uid = srv.submit(x, node=node)
        submit_vt[uid] = at
        step()
    while srv._pending:
        step(force=True)
    return done


def _bench_bursty(fast, rows):
    from repro.core.features import make_random_features
    from repro.serving import BetaStore, ContinuousELMServer, ELMServer

    D, L, M, V = DEFAULT_POINT["D"], DEFAULT_POINT["L"], DEFAULT_POINT["M"], 4
    fmap = make_random_features(jax.random.key(1), D, L)
    betas0 = jax.random.normal(
        jax.random.key(2), (V, L, M), dtype=jnp.float32
    )
    arrivals = _bursty_stream(fast, D)

    def warmed(srv):
        srv.predict(np.zeros((SLOTS, D), np.float32))
        for k in srv.metrics:
            srv.metrics[k] = [] if k == "latencies_s" else 0
        # the warm-up call quantized one node's beta into the int8
        # cache; drop it so the drain's beta_bytes counts every node
        srv._beta_q.clear()
        return srv

    fifo = warmed(ELMServer(fmap, BetaStore(betas0), buckets=(SLOTS,)))
    fifo_done = _drain_fifo(fifo, arrivals, TICK_MS)
    cont = warmed(ContinuousELMServer(fmap, BetaStore(betas0), slots=SLOTS))
    cont_done = _drain_continuous(cont, arrivals)

    assert set(fifo_done) == set(cont_done)
    bitwise = all(
        np.array_equal(fifo_done[u][1], cont_done[u][1]) for u in fifo_done
    )
    out = {"tick_ms": TICK_MS, "slots": SLOTS, "num_requests": len(arrivals)}
    for arm, done, srv in (("fifo", fifo_done, fifo),
                           ("continuous", cont_done, cont)):
        lats = np.asarray([lat for lat, _ in done.values()])
        out[arm] = dict(
            p50_ms=float(np.percentile(lats, 50)),
            p99_ms=float(np.percentile(lats, 99)),
            mean_ms=float(np.mean(lats)),
            batches=srv.metrics["batches"],
        )
        rows.append((
            f"serving/bursty_{arm}_req{len(arrivals)}",
            out[arm]["mean_ms"] * 1e3,
            f"p50_ms={out[arm]['p50_ms']:.2f};"
            f"p99_ms={out[arm]['p99_ms']:.2f};"
            f"batches={out[arm]['batches']}",
        ))
    out["p99_improvement"] = out["fifo"]["p99_ms"] / max(
        out["continuous"]["p99_ms"], 1e-9
    )
    out["bitwise_match"] = bitwise

    # int8-beta arm: the bytes/error tradeoff on the same stream
    q = warmed(ContinuousELMServer(
        fmap, BetaStore(betas0), slots=SLOTS, beta_mode="int8",
    ))
    t0 = time.perf_counter()
    q_done = _drain_continuous(q, arrivals)
    wall_ms = (time.perf_counter() - t0) * 1e3
    err = max(
        float(np.max(np.abs(q_done[u][1] - cont_done[u][1]))
              / (np.max(np.abs(cont_done[u][1])) + 1e-9))
        for u in cont_done
    )
    out["int8"] = dict(
        max_rel_err=err,
        beta_bytes=q.metrics["beta_bytes"],
        wall_ms=wall_ms,
    )
    rows.append((
        f"serving/bursty_int8_req{len(arrivals)}", wall_ms * 1e3,
        f"max_rel_err={err:.4f};beta_bytes={q.metrics['beta_bytes']}",
    ))
    return out


def _bench_multitenant_kernel(fast, rows, records, tune):
    """Stacked-beta launch vs the per-tenant loop over a T sweep.

    The loop subject is T dispatches of the single-beta fused predict
    (one compiled program, per-tenant row slices pre-split out of the
    timed region); the stacked subject is ONE fused_predict_stacked
    launch over the same rows with per-row tenant ids. Same flops on
    both sides — the stacked win is shared dispatch + one program.
    """
    from repro.kernels import autotune
    from repro.kernels.elm_predict_ops import (
        fused_predict,
        fused_predict_stacked,
    )

    backend = jax.default_backend()
    impl = "pallas" if backend == "tpu" else "scan"
    sweep_T = [16, MT_ACCEPT_T] if fast else [16, MT_ACCEPT_T, 256]
    acceptance = None
    for T in sweep_T:
        N = T * MT_ROWS_PER_TENANT
        pt = dict(MT_POINT, N=N, T=T)
        dt = jnp.dtype(pt["dtype"])
        ks = jax.random.split(jax.random.key(0), 4)
        X = jax.random.normal(ks[0], (N, pt["D"])).astype(dt)
        W = jax.random.normal(ks[1], (pt["D"], pt["L"])).astype(dt)
        b = jax.random.normal(ks[2], (pt["L"],)).astype(jnp.float32)
        betas = jax.random.normal(
            ks[3], (T, pt["L"], pt["M"])
        ).astype(jnp.float32)
        # contiguous per-tenant rows so the loop serves clean slices;
        # the stacked kernel is packing-independent per row anyway
        tids = jnp.repeat(
            jnp.arange(T, dtype=jnp.int32), MT_ROWS_PER_TENANT
        )
        if tune:
            tuning = dict(autotune.tune(
                "stacked", N, pt["D"], pt["L"], pt["M"], pt["dtype"],
                impl=impl, T=T, repeats=2 if fast else 3, force=True,
            ))
            tag = "tuned"
        else:
            cfg = autotune.lookup(
                "stacked", N, pt["D"], pt["L"], pt["M"], pt["dtype"],
                impl=impl, T=T,
            )
            tuning = dict(cfg) if cfg is not None else "cached"
            tag = "cached" if cfg is not None else "default"
        X_parts = [
            jax.device_put(X[t * MT_ROWS_PER_TENANT:
                             (t + 1) * MT_ROWS_PER_TENANT])
            for t in range(T)
        ]
        use_kernel = backend == "tpu"

        def loop():
            return [
                fused_predict(
                    X_parts[t], W, b, betas[t],
                    use_kernel=use_kernel, tuning="off",
                )
                for t in range(T)
            ]

        def stacked():
            return fused_predict_stacked(
                X, W, b, betas, tids,
                use_kernel=use_kernel, tuning=tuning,
            )

        reps = 3 if fast else 5
        loop_ms, stacked_ms = paired_timeit_ms([loop, stacked],
                                               repeats=reps)
        rec = dict(
            pt,
            fused_impl=f"stacked-{impl}({tag})",
            backend=backend,
            unfused_wall_ms=loop_ms,
            fused_wall_ms=stacked_ms,
            fused_speedup=loop_ms / max(stacked_ms, 1e-9),
        )
        records.append(rec)
        rows.append((
            f"multitenant/stacked_T{T}_N{N}", stacked_ms * 1e3,
            f"loop_ms={loop_ms:.2f};stacked_ms={stacked_ms:.2f};"
            f"fused_speedup={rec['fused_speedup']:.2f}",
        ))
        if T == MT_ACCEPT_T:
            acceptance = dict(
                point=pt,
                fused_wall_ms=stacked_ms,
                unfused_wall_ms=loop_ms,
                fused_not_slower=stacked_ms <= loop_ms,
            )
            rows.append((
                "multitenant/acceptance_T64", 0.0,
                f"fused_not_slower={acceptance['fused_not_slower']};"
                f"stacked_ms={stacked_ms:.2f};loop_ms={loop_ms:.2f}",
            ))
    return acceptance


def _bench_multitenant_server(fast, rows):
    """The 64-tenant mixed micro-batch through the real server: one
    registry snapshot, one bucket, ONE fused launch."""
    from repro.core.features import make_random_features
    from repro.serving import ELMServer, TenantRegistry

    D, L, M = MT_POINT["D"], MT_POINT["L"], MT_POINT["M"]
    T, R = MT_ACCEPT_T, MT_ROWS_PER_TENANT
    fmap = make_random_features(jax.random.key(1), D, L)
    rng = np.random.default_rng(0)
    reg = TenantRegistry({
        f"user-{i}": rng.standard_normal((L, M)).astype(np.float32)
        for i in range(T)
    })
    srv = ELMServer(fmap, reg, buckets=(T * R,))
    queries = {
        f"user-{i}": rng.standard_normal((R, D)).astype(np.float32)
        for i in range(T)
    }
    # warm the stacked bucket program out of the timed region, then
    # zero the counters so the reported stats describe the measurement
    srv.predict(np.zeros((R, D), np.float32), tenant="user-0")
    for k in srv.metrics:
        srv.metrics[k] = [] if k == "latencies_s" else 0
    reps = 3 if fast else 6
    best_s = float("inf")
    batches_per_flush = None
    for _ in range(reps):
        t0 = time.perf_counter()
        for tenant, q in queries.items():
            srv.submit(q, tenant=tenant)
        out = srv.flush()
        best_s = min(best_s, time.perf_counter() - t0)
        assert len(out) == T
        if batches_per_flush is None:
            batches_per_flush = srv.metrics["batches"]
    one_launch = batches_per_flush == 1
    res = dict(
        tenants=T, rows_per_tenant=R,
        wall_ms=best_s * 1e3,
        rows_per_s=T * R / best_s,
        batches_per_flush=batches_per_flush,
        one_fused_launch=one_launch,
        swaps=srv.metrics["swaps"],
    )
    rows.append((
        f"multitenant/server_T{T}x{R}", best_s * 1e6,
        f"one_fused_launch={one_launch};"
        f"rows_per_s={res['rows_per_s']:.0f}",
    ))
    return res


def bench_multitenant(fast: bool = False, tune: bool = False):
    """Stacked-beta multi-tenant serving; CSV rows + JSON.

    Emits CSV rows and writes BENCH_multitenant.json at the repo root
    (the nightly ``multitenant`` arm; tools/bench_gate.py globs it
    alongside the other BENCH_*.json baselines).
    """
    rows, records = [], []
    acceptance = _bench_multitenant_kernel(fast, rows, records, tune)
    server = _bench_multitenant_server(fast, rows)
    if acceptance is not None:
        acceptance = dict(
            acceptance, one_fused_launch=server["one_fused_launch"]
        )
    payload = dict(
        suite="multitenant",
        backend=jax.default_backend(),
        default_point=dict(
            MT_POINT, T=MT_ACCEPT_T,
            N=MT_ACCEPT_T * MT_ROWS_PER_TENANT,
        ),
        tuned=tune,
        rows=records,
        server=server,
        acceptance=acceptance,
    )
    with open(MT_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append((
        "multitenant/json", 0.0, f"written={os.path.basename(MT_JSON)}"
    ))
    return rows, {"json": MT_JSON}


def bench_serving(fast: bool = False, tune: bool = False):
    """fused-vs-unfused predict + server traffic; CSV rows + JSON.

    Emits CSV rows and writes BENCH_serving.json at the repo root. With
    ``tune=True`` each swept point is re-tuned (sweep-and-cache into
    TUNED_kernels.json) before it is benched.
    """
    rows = []
    records = []
    acceptance = _bench_kernel(fast, rows, records, tune)
    server = _bench_server(fast, rows)
    bursty = _bench_bursty(fast, rows)
    # the stacked-beta rows ride in BENCH_serving.json too (unique
    # identity keys: the multi-tenant N sweep never collides with the
    # single-beta sweep), so the committed-row fused_speedup >= 1.0
    # invariant covers the multi-tenant path from this file as well
    mt_acceptance = _bench_multitenant_kernel(fast, rows, records, tune)
    mt_server = _bench_multitenant_server(fast, rows)
    if acceptance is not None:
        acceptance = dict(
            acceptance,
            continuous_bitwise_match=bursty["bitwise_match"],
            continuous_p99_improved=bursty["p99_improvement"] > 1.0,
            multitenant_one_fused_launch=mt_server["one_fused_launch"],
            multitenant_stacked_not_slower=(
                mt_acceptance["fused_not_slower"]
                if mt_acceptance else None
            ),
        )

    payload = dict(
        suite="serving",
        backend=jax.default_backend(),
        default_point=DEFAULT_POINT,
        tuned=tune,
        rows=records,
        server=server,
        bursty=bursty,
        multitenant=dict(mt_server, acceptance=mt_acceptance),
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append((
        "serving/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"
    ))
    return rows, {"json": BENCH_JSON}
