"""Continuous batching: requests of different lengths share decode slots.

Three requests, two slots — slot 0 finishes early and is refilled
mid-flight while slot 1 keeps decoding. Output is token-identical to
generating each request alone (tests/test_serving.py proves it).

Run:  PYTHONPATH=src python examples/continuous_batching.py
"""

import time

import jax
import numpy as np

from repro.configs import get
from repro.models import Model
from repro.serving import ContinuousBatchingEngine, Request

cfg = get("h2o-danube-1.8b").reduced()
model = Model(cfg)
params = model.init(jax.random.key(0))

rng = np.random.default_rng(0)
requests = [
    Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 12), max_new=5),
    Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 30), max_new=10),
    Request(uid=2, prompt=rng.integers(0, cfg.vocab_size, 8), max_new=7),
]

engine = ContinuousBatchingEngine(model, params, slots=2, max_seq=96)
for r in requests:
    engine.submit(r)
    print(f"submitted request {r.uid}: prompt={len(r.prompt)} tokens, "
          f"max_new={r.max_new}")

t0 = time.time()
results = engine.run()
dt = time.time() - t0
total = sum(len(v) for v in results.values())
print(f"\ndecoded {total} tokens across {len(results)} requests in {dt:.1f}s")
for uid in sorted(results):
    print(f"request {uid}: {results[uid]}")
