"""Quickstart: train a DC-ELM across a 4-node network in ~20 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import consensus, dc_elm, elm
from repro.data.sinc import make_sinc_dataset

# 1. A network of 4 nodes (the paper's Fig. 2 ring) with local datasets.
graph = consensus.paper_fig2()
X, Y, X_test, Y_test = make_sinc_dataset(jax.random.key(0))  # (V, N_i, 1)

# 2. Run DC-ELM (Algorithm 1): local ridge solves + neighbor gossip.
C = 2.0**4  # f32-friendly; examples/sinc_regression.py runs C=2^8 in f64
fmap, final, _ = dc_elm.simulate_train(
    jax.random.key(1),
    X, Y,
    num_features=100,
    C=C,
    graph=graph,
    gamma=1 / 2.1,  # < 1/d_max = 0.5 (Theorem 2)
    num_iters=500,
)

# 3. Every node now holds (nearly) the centralized solution.
H = jax.vmap(fmap)(X)
beta_central = elm.ridge_solve(H.reshape(-1, 100), Y.reshape(-1, 1), C)
for i in range(graph.num_nodes):
    node = elm.ELM(feature_map=fmap, beta=final.betas[i])
    print(f"node {i}: test MSE = {float(elm.mse(node, X_test, Y_test)):.5f}")
central = elm.ELM(feature_map=fmap, beta=beta_central)
print(f"centralized test MSE = {float(elm.mse(central, X_test, Y_test)):.5f}")
print(f"max relative distance to centralized: "
      f"{float(dc_elm.distance_to(final.betas, beta_central)):.4f}")
