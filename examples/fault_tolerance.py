"""Fault tolerance: DC-ELM degrades gracefully, the fusion center stalls.

Three scenes on a 16-node hypercube:

1. **Bernoulli link dropout** — every link independently drops each
   round with probability p. A `consensus.FaultModel` certifies the
   trace stays jointly connected and a `FaultyMixer` replays it; DC-ELM
   keeps converging to the centralized solution, just needing more
   rounds as p grows.

2. **Node crash / rejoin** — a node's links all die for a burst and
   come back. The survivors keep consenting among themselves; the
   crashed node is pulled back to the network solution after rejoining.

3. **Fusion-center contrast** — the parallel-ELM baseline
   (`core/fusion_elm`) reduces (P_i, Q_i) with one all-reduce. That
   barrier needs *every* chip: while any node is down the reduction
   blocks and the fusion answer simply does not exist, whereas DC-ELM's
   live nodes kept improving the whole time (DESIGN.md §6).

Streaming churn (a node's *data* leaving/joining the problem, not just
its links) is `ConsensusEngine.stream_leave` / `stream_join`.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, engine, fusion_elm

V, Ni, L, M, C = 16, 48, 12, 1, 0.05
ROUNDS = 3000

ks = jax.random.split(jax.random.key(0), 2)
H = jax.random.normal(ks[0], (V, Ni, L))
T = jax.random.normal(ks[1], (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
dist = lambda b: float(dc_elm.distance_to(b, beta_star))  # noqa: E731

graph = consensus.build("hypercube", V)
gamma = graph.default_gamma()

# sanity: the fusion-center baseline and the consensus target agree
beta_fusion = fusion_elm.simulate(H, T, C)
assert float(jnp.max(jnp.abs(beta_fusion - beta_star))) < 1e-4

print(f"== 1. Bernoulli link dropout ({V}-node hypercube, "
      f"{ROUNDS} rounds) ==")
for p in [0.0, 0.1, 0.2, 0.3]:
    fm = consensus.FaultModel.sample_certified(
        graph, p, num_rounds=ROUNDS, window=16
    )
    eng = engine.with_faults(engine.simulated_dc_elm(graph, C), fm, ROUNDS)
    betas, _ = eng.run(state.betas, state.omegas, gamma, ROUNDS)
    print(f"  p={p:.1f}: distance to centralized = {dist(betas):.2e}")

print("\n== 2. Node crash / rejoin ==")
crash = consensus.NodeCrash(node=3, start=300, duration=600)
fm = consensus.FaultModel(graph=graph, crashes=(crash,))
eng = engine.with_faults(engine.simulated_dc_elm(graph, C), fm, ROUNDS)
betas, traces = eng.run(
    state.betas, state.omegas, gamma, ROUNDS,
    trace_fn=lambda b: dc_elm.distance_to(b, beta_star),
)
traces = np.asarray(traces)
print(f"  node {crash.node} down for rounds "
      f"[{crash.start}, {crash.start + crash.duration})")
for k in [crash.start, crash.start + crash.duration, ROUNDS]:
    print(f"  after round {k:4d}: distance = {traces[k - 1]:.2e}")

print("\n== 3. Fusion-center baseline under the same crash ==")
down = crash.duration
print("  DC-ELM rounds stalled by the crash:      0 "
      "(gossip loses only that node's links)")
print(f"  fusion all-reduce rounds stalled:        {down} "
      f"(barrier needs all {V} chips)")
alive = [i for i in range(V) if i != crash.node]
beta_partial = fusion_elm.simulate(H[jnp.asarray(alive)],
                                   T[jnp.asarray(alive)], C)
err = float(jnp.max(jnp.abs(beta_partial - beta_star)))
print(f"  restarting fusion WITHOUT the crashed chip answers a "
      f"different problem:\n"
      f"    ||beta(V-1 nodes) - beta*|| = {err:.3f} "
      f"(the crashed node's data is gone)")
print(f"  DC-ELM distance at the same moment: {traces[crash.start + down - 1]:.2e}")
