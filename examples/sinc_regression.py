"""Paper Test Case 1 in full: SinC regression, all three Fig. 4 settings,
including the documented divergence at gamma > 1/d_max.

Run:  PYTHONPATH=src python examples/sinc_regression.py
"""

import jax

jax.config.update("jax_enable_x64", True)  # stiff C=2^8 solves, like MATLAB

import jax.numpy as jnp  # noqa: E402

from repro.core import consensus, dc_elm, elm  # noqa: E402
from repro.core.features import make_random_features  # noqa: E402
from repro.data.sinc import make_sinc_dataset  # noqa: E402

graph = consensus.paper_fig2()
X, Y, X_test, Y_test = make_sinc_dataset(jax.random.key(0))
X, Y = X.astype(jnp.float64), Y.astype(jnp.float64)
fmap = make_random_features(jax.random.key(1), 1, 100, dtype=X.dtype)

print(f"network: {graph.name}, d_max={graph.d_max:.0f} "
      f"=> gamma must be < {graph.gamma_upper_bound():.3f}")

for tag, C, gamma in [
    ("(a) C=2^2, gamma=1/1.9  [diverges]", 2.0**2, 1 / 1.9),
    ("(b) C=2^2, gamma=1/2.1", 2.0**2, 1 / 2.1),
    ("(c) C=2^8, gamma=1/2.1", 2.0**8, 1 / 2.1),
]:
    # raw-input init: Algorithm 1 steps 1-3 through the statistics
    # plane (core/stats.py) — the hidden matrices stay implicit
    state, P_, Q_ = dc_elm.simulate_init_raw(X, Y, fmap, C)
    trace = dc_elm.average_empirical_risk_fn(fmap, X_test, Y_test)
    # check_gamma=False: setting (a) deliberately exceeds the Thm. 2
    # bound to reproduce the paper's divergence panel
    final, risks = dc_elm.simulate_run(state, graph, gamma, C, 300,
                                       trace_fn=trace, check_gamma=False)
    beta_c = dc_elm.centralized_from_node_stats(P_, Q_, C)
    cent = elm.ELM(feature_map=fmap, beta=beta_c)
    r_c = float(elm.empirical_risk(cent(X_test), Y_test))
    print(f"{tag}")
    print(f"    centralized risk R_c = {r_c:.4f}")
    print(f"    DC-ELM risk R_d: k=0 {float(risks[0]):.4f} -> "
          f"k=300 {float(risks[-1]):.4g}")
    print(f"    distance to centralized: "
          f"{float(dc_elm.distance_to(final.betas, beta_c)):.4g}")
