"""Serve-while-train: online DC-ELM answering queries as it learns.

The paper's Algorithm 2 keeps a usable model at every node at every
round; this example closes the loop with the serving plane. A 4-node
network streams SinC chunks through ``ConsensusEngine.stream_chunk``,
publishing each post-consensus beta snapshot into a ``BetaStore``
(``publish_to=``). A live ``ELMServer`` answers a mixed-size query
stream against the same store the whole time — micro-batched into
padded buckets over the fused predict kernel, hot-swapping onto every
new version mid-traffic — so the served test MSE falls round over round
while queries keep getting answers.

Run:  PYTHONPATH=src python examples/elm_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, engine
from repro.core.features import make_random_features
from repro.data.sinc import make_sinc_dataset, sinc
from repro.serving import BetaStore, ELMServer

V, L, C = 4, 100, 2.0**6
graph = consensus.paper_fig2()
fmap = make_random_features(jax.random.key(1), 1, L)
eng = engine.simulated_dc_elm(graph, C)

# warm-up shard per node, then the store's version 1 goes live
X, Y, X_test, Y_test = make_sinc_dataset(jax.random.key(0), num_nodes=V,
                                         per_node=100)
state = eng.stream_init(X_nodes=X, T_nodes=Y, feature_map=fmap)
store = BetaStore()
state, _ = eng.stream_chunk(
    state, gamma=1 / 2.1, num_iters=200, publish_to=store
)

server = ELMServer(fmap, store, buckets=(16, 64, 256))
rng = np.random.default_rng(0)
stream_key = jax.random.key(7)

for step in range(6):
    # training plane: every node receives a fresh chunk of 50 samples,
    # runs the Algorithm 2 event, and publishes the new consensus betas
    stream_key, k1, k2 = jax.random.split(stream_key, 3)
    Xn = jax.random.uniform(k1, (V, 50, 1), minval=-10, maxval=10)
    Yn = sinc(Xn) + jax.random.uniform(
        k2, (V, 50, 1), minval=-0.2, maxval=0.2
    )
    state, _ = eng.stream_chunk(
        state, added=(jax.vmap(fmap)(Xn), Yn), gamma=1 / 2.1,
        num_iters=200, publish_to=store,
    )

    # serving plane: mid-stream query traffic of varying row counts,
    # answered by whichever node replica is next in the rotation with
    # whatever beta version the flush hot-swapped onto
    queries = {}
    for n in (3, 17, 40, 5):
        q = rng.uniform(-10, 10, (n, 1)).astype(np.float32)
        queries[server.submit(q)] = q
    responses = server.flush()
    # score the served answers against the noise-free truth
    served_sq = np.concatenate([
        (r.y - np.asarray(sinc(jnp.asarray(queries[r.uid])))) ** 2
        for r in responses
    ])
    # test-set view of the same published model
    test_pred = server.predict(np.asarray(X_test, np.float32))
    test_mse = float(np.mean((test_pred - np.asarray(Y_test)) ** 2))
    st = server.stats()
    print(
        f"chunk {step}: serving v{server.served_version} "
        f"(store v{store.version}), {len(responses)} responses, "
        f"served MSE {float(np.mean(served_sq)):.5f}, "
        f"test MSE {test_mse:.5f}, p50 {st['p50_ms']:.1f} ms"
    )

assert test_mse < 5e-3, "serve-while-train did not converge"
print(f"final served test MSE {test_mse:.5f} after {store.version} publishes")
