"""Compressed gossip: shrinking DC-ELM's wire traffic 10x.

The paper motivates DC-ELM for networks where "the amount of
information exchanging" is the binding constraint (Sec. V). This
walkthrough builds up the compression subsystem (DESIGN.md §9) on a
16-node hypercube, scene by scene:

1. **Wire formats** — bf16 cast, int8 stochastic quantization with
   per-tile scales, top-k sparsification. Every scheme converges to
   the same centralized solution; the engine reports exact
   bytes-on-wire for each (`ConsensusEngine.wire_stats`).

2. **Error feedback** — why int8 gossip has *no* quantization floor
   here: each node transmits the quantized difference against its
   public replica (CHOCO-style), so the quantizer's scale decays with
   the residual. The memoryless ablation (`error_feedback=False`)
   shows the floor you'd get without the memory.

3. **Event-triggered rounds** — nodes whose residual moved less than
   a threshold stay silent (zero bytes). In a reach-and-hold window
   the network goes quiet after convergence: ~10x fewer bytes than
   fp32 at the same tolerance.

4. **Stacking with faults** — `with_faults` slides the fault layer
   under the compression layer, so encoded payloads cross whatever
   links the certified trace left alive; convergence and exact
   live-link byte accounting survive.

Run:  PYTHONPATH=src python examples/compressed_gossip.py
"""

import jax
import numpy as np

from repro.core import consensus, dc_elm, engine
from repro.core.compression import CompressionSpec

V, Ni, L, M, C = 16, 48, 32, 4, 0.5
ROUNDS = 1200

ks = jax.random.split(jax.random.key(0), 2)
H = jax.random.normal(ks[0], (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(ks[1], (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
dist = lambda b: float(dc_elm.distance_to(b, beta_star))  # noqa: E731

graph = consensus.build("hypercube", V)
gamma = graph.default_gamma()


def show(name, eng, gamma=gamma, rounds=ROUNDS):
    betas, _ = eng.run(state.betas, state.omegas, gamma, rounds)
    ws = eng.wire_stats
    print(f"  {name:<16s} dist={dist(betas):.2e}  "
          f"bytes={ws.bytes_on_wire/1e6:7.2f}MB  "
          f"ratio={ws.compression_ratio:5.3f}  "
          f"silent_links={ws.links_skipped}/{ws.links_live}")
    return betas


print(f"== 1. Wire formats ({V}-node hypercube, {ROUNDS} rounds) ==")
show("fp32", engine.simulated_dc_elm(graph, C))
show("bf16", engine.simulated_dc_elm(graph, C,
                                     compress=CompressionSpec("bf16")))
show("int8 (t=128)", engine.simulated_dc_elm(
    graph, C, compress=CompressionSpec("int8", tile=128)))
# top-k transmits 10% of entries; CHOCO theory asks for a smaller
# consensus gain when the compressor keeps this little per round
show("topk 10%", engine.simulated_dc_elm(
    graph, C, compress=CompressionSpec("topk", k=0.1)), gamma=0.3 * gamma)

print("\n== 2. Error feedback: replica memory removes the floor ==")
show("int8 + EF", engine.simulated_dc_elm(
    graph, C, compress=CompressionSpec("int8", tile=128)))
show("int8, no EF", engine.simulated_dc_elm(
    graph, C,
    compress=CompressionSpec("int8", tile=128, error_feedback=False)))
print("  (no-EF is stuck ~3 decades higher: each round re-quantizes the "
      "full-scale state,\n   EF quantizes a residual that shrinks 127x "
      "per round)")

print("\n== 3. Event-triggered rounds: converge, then go quiet ==")
spec = CompressionSpec("int8", tile=128, event_threshold=1e-3)
eng = engine.simulated_dc_elm(graph, C, compress=spec)
betas, _ = eng.run(state.betas, state.omegas, gamma, ROUNDS)
ws = eng.wire_stats
fp32_bytes = ws.bytes_uncompressed
duty = ws.per_round_bytes / max(ws.per_round_bytes.max(), 1)
print(f"  dist={dist(betas):.2e}  bytes={ws.bytes_on_wire/1e6:.2f}MB "
      f"vs fp32 {fp32_bytes/1e6:.2f}MB -> {ws.compression_ratio:.1%}")
print(f"  broadcast duty cycle: first 50 rounds {duty[:50].mean():.0%}, "
      f"last 50 rounds {duty[-50:].mean():.0%}")

print("\n== 4. Stacked with a certified fault trace (20% link dropout) ==")
fm = consensus.FaultModel.sample_certified(graph, 0.2, num_rounds=64,
                                           window=16)
eng = engine.with_faults(
    engine.simulated_dc_elm(graph, C, compress=spec), fm.edge_keep(64)
)
print(f"  mixer stack: {type(eng.mixer).__name__}"
      f"({type(eng.mixer.base).__name__})")
show("int8+EF+event", eng)
print("  (bytes count only live links; dropped links move nothing and "
      "silent nodes send nothing)")
