"""Paper Test Case 2: distributed 3-vs-6 digit classification.

25 sensor nodes on a random geometric graph each hold 400 local images;
DC-ELM learns a global classifier without any node sharing raw pixels.

Run:  PYTHONPATH=src python examples/mnist_classification.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, elm
from repro.data.partition import partition_equal
from repro.data.synthetic_mnist import make_mnist36_dataset

V, L, C, gamma = 25, 25, 2.0**-2, 0.076  # paper Fig. 7(a) settings

X, T, X_test, T_test = make_mnist36_dataset(seed=0)
graph = consensus.random_geometric(V, radius=0.35, seed=1)
print(f"network: V={V}, lambda2={graph.algebraic_connectivity:.4f}, "
      f"d_max={graph.d_max:.0f}")

Xn, Tn = partition_equal(X, T, V)  # (25, 400, 784)
print(f"each node holds {Xn.shape[1]} images; none are ever transmitted")

cent = elm.train_centralized(
    jax.random.key(0), jnp.asarray(X), jnp.asarray(T), num_features=L, C=C
)
acc_c = float(elm.accuracy(cent(jnp.asarray(X_test)), jnp.asarray(T_test)))

# raw pixels -> per-node moments via the statistics plane; the
# (400, L) hidden matrices are never stacked in memory
state, _, _ = dc_elm.simulate_init_raw(
    jnp.asarray(Xn), jnp.asarray(Tn), cent.feature_map, C
)
trace = dc_elm.test_error_fn(cent.feature_map, jnp.asarray(X_test),
                             jnp.asarray(T_test))
final, errs = dc_elm.simulate_run(state, graph, gamma, C, 1500,
                                  trace_fn=trace)
errs = np.asarray(errs)
for k in [0, 10, 100, 500, 1499]:
    print(f"iter {k:5d}: average test error {errs[k]:.4f}")
print(f"centralized accuracy: {acc_c:.4f} "
      f"(paper reports 0.8989 on real MNIST for this setup)")
print(f"DC-ELM accuracy:      {1 - errs[-1]:.4f}")
