"""End-to-end driver: consensus-train a ~100M-param LM for a few hundred
steps (the beyond-paper D-PSGD extension, DESIGN.md §3).

Each consensus node holds its own replica + local token stream; after
every optimizer step the replicas mix with graph neighbors using the
paper's rule. On one CPU device this runs V=2 nodes of a ~100M model;
on a pod the identical code runs V=16 nodes of the full architectures
(launch/train.py --devices production).

Run:  PYTHONPATH=src python examples/decentralized_lm_train.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import consensus, dsgd, engine
from repro.data.lm import TokenStream
from repro.models import Model
from repro.optim import adamw, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    # CPU-demo defaults (~10 s/step on one core). On real hardware use
    # e.g. --steps 300 --batch 8 --seq 1024, or launch/train.py with
    # --devices production for the full assigned architectures.
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: a scaled-down starcoder2 family member
    cfg = dataclasses.replace(
        get("starcoder2-3b"),
        name="starcoder2-100m",
        num_layers=6,
        d_model=768,
        num_heads=12,
        num_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab_size=16384,
        dtype="float32",
        remat=False,
    )
    model = Model(cfg)
    print(f"{cfg.name}: {cfg.param_count():,} params, V={args.nodes} nodes")

    V = args.nodes
    graph = consensus.ring(V) if V > 2 else consensus.line(V)
    opt = adamw(linear_warmup_cosine(3e-4, 20, args.steps))
    # the same ConsensusEngine driver as DC-ELM, with the identity-metric
    # AverageRule mixing parameter pytrees after each optimizer step
    eng = engine.simulated_averaging(
        jnp.asarray(graph.adjacency, jnp.float32)
    )
    step = dsgd.make_simulated_train_step(
        lambda p, b: model.loss(p, b)[0], opt,
        gamma=graph.default_gamma(), engine=eng,
    )
    state = dsgd.init_simulated(jax.random.key(0), model.init, opt, V)

    stream = TokenStream(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    def batch():
        t = stream.sample(rng, V * args.batch, args.seq)
        t = t.reshape(V, args.batch, args.seq + 1)
        return {
            "tokens": jnp.asarray(t[..., :-1], jnp.int32),
            "labels": jnp.asarray(t[..., 1:], jnp.int32),
        }

    t0 = time.time()
    for i in range(args.steps):
        state, losses = step(state, batch())
        if i % 25 == 0 or i == args.steps - 1:
            cd = float(dsgd.consensus_distance(state.params))
            print(
                f"step {i:4d} loss/node {np.asarray(losses).round(3)} "
                f"consensus_dist {cd:.2e} ({time.time()-t0:.0f}s)"
            )
    print("done — replicas agree and the loss fell without any gradient "
          "all-reduce (neighbor gossip only).")


if __name__ == "__main__":
    main()
