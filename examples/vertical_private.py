"""Vertical DC-ELM: four institutions, one customer base, zero sharing.

A finance-style scene: four institutions each hold a different
*feature set* about the same customers — a retail bank sees balances
and transaction velocity, a card issuer sees spend categories, a
credit bureau sees repayment history, a payroll processor sees income
stability. Together the features predict a risk score; separately
none of them can, and none of them may ship raw columns to anyone
else.

Vertically partitioned DC-ELM (core/vertical.py, after arXiv
1602.02899) fits exactly this shape: the hidden layer is
H = g(X @ W + b), and matmul distributes over column blocks, so
institution i computes the partial preactivation
Z_i = X[:, lo:hi] @ W[lo:hi, :] locally and only *that* leaves the
building. A spanning-tree reduction over the inter-institution
network assembles Z = sum_i Z_i before the nonlinearity; with secure
aggregation on, every payload on the wire is a masked fixed-point
partial sum whose pairwise masks cancel exactly in the total
(core/secure.py) — the aggregator learns the sum and nothing else.

Four scenes:

1. **Train without pooling data** — the securely assembled (P, Q)
   match the pooled-data moments on the fixed-point grid, so the
   ridge readout is the model a central warehouse would have built.
2. **What the wire saw** — capture every payload and check none of
   them equals any institution's raw partials.
3. **An institution goes dark mid-round** — crash-time mask recovery
   closes out the dropped node's mask residue; the survivors' model
   is exactly the survivor-cohort model, not garbage.
4. **Consensus on top** — seed a DC-ELM state from the vertical init
   and gossip a few rounds: the distributed fixed point *is* the
   centralized solution (paper Thm. 2).

Run:  PYTHONPATH=src python examples/vertical_private.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, stats, vertical
from repro.core.consensus import FaultModel, NodeCrash
from repro.core.secure import SecureAggregationSpec, encode_fixed

INSTITUTIONS = (
    ("retail bank", 5),       # balances, velocity, tenure...
    ("card issuer", 4),       # spend mix
    ("credit bureau", 6),     # repayment history
    ("payroll processor", 3), # income stability
)
N, L, C = 2048, 64, 10.0
V = len(INSTITUTIONS)
D = sum(w for _, w in INSTITUTIONS)

rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
# the "risk score" depends on features no single institution holds
w_true = rng.standard_normal(D) / np.sqrt(D)
t = np.tanh(np.asarray(X) @ w_true) + 0.05 * rng.standard_normal(N)
T = jnp.asarray(t[:, None], jnp.float32)

widths = tuple(w for _, w in INSTITUTIONS)
part = vertical.ColumnPartition.from_widths(widths)
fmap = vertical.make_vertical_map(
    jax.random.key(0), D, L, V, partition=part
)
X_slices = fmap.partition.split(X)   # institution i keeps slice i
graph = consensus.line(V)            # bilateral links, no star hub
spec = SecureAggregationSpec(seed=7)

print(f"== 1. Train without pooling data ({V} institutions, "
      f"N={N}, D={D}, L={L}) ==")
beta, s, rep = vertical.vertical_train(
    X_slices, T, fmap, C, graph=graph, secure=spec
)
P0, Q0 = stats.raw_moments(X, T, fmap)
beta0 = stats.ridge_solve_moments(P0, Q0, C)
gap = float(jnp.max(jnp.abs(beta - beta0)))
mse = float(jnp.mean((fmap(X) @ beta - T) ** 2))
print(f"  ||beta_secure - beta_pooled||_inf = {gap:.2e} "
      f"(fixed-point grid: 2^-{spec.frac_bits})")
print(f"  test-style MSE of the joint model:  {mse:.4f}")
print(f"  bytes on the wire (masked):         "
      f"{rep.wire.bytes_on_wire:,}")
assert gap < 1e-4

print("\n== 2. What the wire saw ==")
partials = [
    fmap.partial_preactivation(i, x) for i, x in enumerate(X_slices)
]
_, cap = vertical.reduce_partials(
    partials, graph, secure=spec, capture_payloads=True
)
raw = [
    encode_fixed(
        np.asarray(p, np.float64).reshape(-1), spec.frac_bits
    )
    for p in partials
]
leaks = sum(
    np.array_equal(payload, r)
    for payload in cap.payloads.values()
    for r in raw
)
print(f"  captured payloads: {len(cap.payloads)}; "
      f"payloads equal to someone's raw partials: {leaks}")
assert leaks == 0

print("\n== 3. An institution goes dark mid-round ==")
dark = 2  # the credit bureau's link drops mid-reduction
fm = FaultModel(
    graph=graph, crashes=(NodeCrash(node=dark, start=1, duration=9),)
)
Z_rec, rep_rec = vertical.reduce_partials(
    partials, graph, secure=spec, faults=fm, start_round=0
)
survivors = rep_rec.delivered
want = np.sum(np.stack([partials[i] for i in survivors]), axis=0)
err = float(np.max(np.abs(np.asarray(Z_rec) - want)))
print(f"  {INSTITUTIONS[dark][0]} dropped; survivors: {survivors}")
print(f"  |recovered - survivor sum|_inf = {err:.2e} "
      f"(mask residue reconstructed, not leaked)")
assert err < 1e-4

print("\n== 4. Consensus on top (paper Thm. 2) ==")
state, s_init, _ = vertical.simulate_init(
    X_slices, T, fmap, C, graph, secure=spec
)
gamma = 0.5 * graph.gamma_upper_bound()
final, _ = dc_elm.simulate_run(state, graph, gamma, C, 25)
drift = float(
    jnp.max(jnp.abs(final.betas - beta0[None]))
)
print(f"  after 25 gossip rounds, max node drift from the pooled "
      f"solution: {drift:.2e}")
assert drift < 1e-3
print("\nall scenes OK")
