"""Online DC-ELM (Algorithm 2): chunk-by-chunk streaming with expiry.

Each node receives new samples and drops expired ones; the engine's
streaming driver (`ConsensusEngine.stream_chunk`) runs the full
Algorithm 2 event — Woodbury add/remove in O(L^2 dN), beta re-seed at
the new local optimum, K consensus rounds — per chunk. The identical
driver runs sharded on a device mesh (see tests/test_engine.py); here it
uses the simulated DenseMixer on the paper's Fig. 2 network.

Run:  PYTHONPATH=src python examples/online_streaming.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import consensus, engine
from repro.core.features import make_random_features
from repro.data.sinc import make_sinc_dataset

V, L, C = 4, 100, 2.0**6
WINDOW = 3  # chunks kept per node before they expire
graph = consensus.paper_fig2()
key = jax.random.key(0)
fmap = make_random_features(jax.random.key(1), 1, L)

eng = engine.simulated_dc_elm(graph, C)

# initial data: a small warm-up set per node — raw-input stream_init
# runs the fused feature->moment path (core/stats.py)
X, Y, X_test, Y_test = make_sinc_dataset(key, num_nodes=V, per_node=100)
state = eng.stream_init(X_nodes=X, T_nodes=Y, feature_map=fmap)

stream_key = jax.random.key(7)
H_test = fmap(X_test)
live_chunks = []  # sliding window of (H, T) chunks still in the model

for step in range(6):
    # each node receives a fresh chunk of 50 samples...
    stream_key, k1, k2 = jax.random.split(stream_key, 3)
    Xn = jax.random.uniform(k1, (V, 50, 1), minval=-10, maxval=10)
    Yn = jnp.sin(Xn) / jnp.where(Xn == 0, 1.0, Xn) + jax.random.uniform(
        k2, (V, 50, 1), minval=-0.2, maxval=0.2
    )
    added = (jax.vmap(fmap)(Xn), Yn)
    # ...and the oldest chunk expires once the window is full
    removed = live_chunks.pop(0) if len(live_chunks) >= WINDOW else None
    live_chunks.append(added)

    t0 = time.perf_counter()
    state, _ = eng.stream_chunk(
        state, added=added, removed=removed, gamma=1 / 2.1, num_iters=200
    )
    jax.block_until_ready(state.betas)
    dt = time.perf_counter() - t0
    preds = jnp.einsum("nl,vlm->vnm", H_test, state.betas)
    mse = float(jnp.mean((preds - Y_test[None]) ** 2))
    what = "+50" + ("/-50" if removed is not None else "")
    print(f"chunk {step}: {what} samples/node, update+consensus in "
          f"{dt*1e3:.0f} ms, network test MSE {mse:.5f}")
