"""Online DC-ELM (Algorithm 2): chunk-by-chunk streaming with expiry.

Each node receives new samples and drops expired ones; the Woodbury
updates keep per-chunk cost at O(L^2 dN) instead of O(L^3) re-solves.

Run:  PYTHONPATH=src python examples/online_streaming.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import consensus, dc_elm, online
from repro.core.features import make_random_features
from repro.data.sinc import make_sinc_dataset

V, L, C = 4, 100, 2.0**6
graph = consensus.paper_fig2()
key = jax.random.key(0)
fmap = make_random_features(jax.random.key(1), 1, L)

# initial data: a small warm-up set per node
X, Y, X_test, Y_test = make_sinc_dataset(key, num_nodes=V, per_node=100)
H0 = jax.vmap(fmap)(X)
states = jax.vmap(lambda h, t: online.init_state(h, t, C, V))(H0, Y)

stream_key = jax.random.key(7)
H_test = fmap(X_test)

for step in range(6):
    # each node receives a fresh chunk of 50 samples...
    stream_key, k1, k2 = jax.random.split(stream_key, 3)
    Xn = jax.random.uniform(k1, (V, 50, 1), minval=-10, maxval=10)
    Yn = jnp.sin(Xn) / jnp.where(Xn == 0, 1.0, Xn) + jax.random.uniform(
        k2, (V, 50, 1), minval=-0.2, maxval=0.2
    )
    t0 = time.perf_counter()
    states = online.batched_add_chunk(states, jax.vmap(fmap)(Xn), Yn)
    # ...then re-seed the consensus iteration from the updated stats
    betas = online.reseed_betas(states)
    dc_state = dc_elm.DCELMState(
        betas=betas, omegas=states.omega, k=jnp.zeros((), jnp.int32)
    )
    final, _ = dc_elm.simulate_run(dc_state, graph, 1 / 2.1, C, 200)
    jax.block_until_ready(final.betas)
    dt = time.perf_counter() - t0
    preds = jnp.einsum("nl,vlm->vnm", H_test, final.betas)
    mse = float(jnp.mean((preds - Y_test[None]) ** 2))
    print(f"chunk {step}: +50 samples/node, update+consensus in "
          f"{dt*1e3:.0f} ms, network test MSE {mse:.5f}")
